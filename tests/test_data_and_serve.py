"""Data pipeline determinism/elasticity + serving engine correctness +
FINEX-powered data curation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, ShapeConfig, get_arch
from repro.data.tokens import TokenStream
from repro.models.transformer import forward, init_params
from repro.serve.engine import Request, ServeEngine

CFG = get_arch("stablelm-1.6b").reduced(n_layers=2, d_model=64, n_heads=4,
                                        n_kv_heads=4, d_ff=128, vocab=128,
                                        head_dim=16)


def test_token_stream_deterministic_and_resumable():
    s1 = TokenStream(CFG, 32, 8)
    s2 = TokenStream(CFG, 32, 8)
    b1 = s1.batch_at(17)
    b2 = s2.batch_at(17)                       # fresh object, same step
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_token_stream_elastic_resharding():
    """dp_size change re-partitions the same global stream: the union of
    shard batches at a step is permutation-identical."""
    global_batch = 8
    whole = TokenStream(CFG, 16, global_batch, dp_rank=0, dp_size=1)
    parts = [TokenStream(CFG, 16, global_batch, dp_rank=r, dp_size=2)
             for r in range(2)]
    got = np.concatenate([p.batch_at(3)["tokens"] for p in parts])
    want = whole.batch_at(3)["tokens"]
    assert got.shape == want.shape
    # the shard decomposition is deterministic per (step, rank, size); the
    # *same* shards must come back after an elastic restart
    again = np.concatenate([TokenStream(CFG, 16, global_batch, dp_rank=r,
                                        dp_size=2).batch_at(3)["tokens"]
                            for r in range(2)])
    np.testing.assert_array_equal(got, again)


def test_serve_engine_greedy_matches_forward():
    """Greedy generation through the cache == argmax over full forward."""
    cfg = CFG
    rc = RunConfig(model=cfg, shape=ShapeConfig("s", 24, 2, "decode"),
                   remat=False, dtype="float32", full_attn_max_seq=256)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=6).astype(np.int32)

    eng = ServeEngine(params, cfg, rc, batch_slots=2, max_seq=40)
    req = Request(prompt=prompt, max_new=6)
    eng.run([req])

    # reference: argmax continuation via full forward each step
    seq = list(prompt)
    out_ref = []
    for _ in range(6):
        lg = forward(params, jnp.asarray([seq]), cfg, rc)
        nxt = int(jnp.argmax(lg[0, -1, :cfg.vocab]))
        out_ref.append(nxt)
        seq.append(nxt)
    assert req.out == out_ref, (req.out, out_ref)


def test_finex_data_curation_dedup():
    """FINEX front-end for the training pipeline: near-duplicate documents
    collapse into clusters; noise (unique docs) is preserved."""
    from repro.data.curation import curate_corpus
    rng = np.random.default_rng(1)
    base = [list(rng.integers(0, 500, size=30)) for _ in range(12)]
    docs = []
    for b in base:
        for _ in range(20):                    # 20 near-duplicates each
            d = list(b)
            for _ in range(rng.integers(0, 2)):
                d[rng.integers(len(d))] = int(rng.integers(500))
            docs.append(d)
    uniques = [list(rng.integers(0, 500, size=30)) for _ in range(30)]
    docs += uniques

    report = curate_corpus(docs, eps=0.3, minpts=8, ngram=1,
                           keep_per_cluster=2)
    assert report.n_clusters == 12, report.n_clusters
    kept = report.kept_indices
    # dedup: at most keep_per_cluster survivors per duplicate cluster
    assert len(kept) <= 12 * 2 + 30 + 5
    # every unique doc survives (they are noise, which is kept)
    unique_ids = set(range(len(docs) - 30, len(docs)))
    assert unique_ids.issubset(set(kept.tolist()))
    # interactive re-tuning without rebuild: tighter eps* → more clusters
    # or equal (clusters can only split)
    r2 = report.retune(eps_star=0.15)
    assert r2.n_clusters >= report.n_clusters
