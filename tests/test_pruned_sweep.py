"""Property suite for the projection-pruned exact sweep.

The screen's one contract: pruning only ever removes *provable* non-hits,
so the pruned sweep must be byte-identical — CSR indices, CSR distance
bits, counts — to the unpruned sweep, for every registered metric, every
geometry, every emit path, on one device and on a mesh.  These tests
randomize over metrics, adversarial geometries (everything-hits,
far-separated blobs, exact duplicates) and the incremental insert strip,
always comparing ``prune="on"`` against ``prune="off"`` bit for bit.

Engines here use small ``batch_rows``/``screen_bucket`` so the true
sub-corpus screened path (not just the hybrid full-tile escape) engages
at test-sized n.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import FinexIndex
from repro.metrics import CallableMetric, get_metric, registered_metrics
from repro.neighbors.engine import NeighborEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ALL_METRICS = registered_metrics()

# engine kwargs that force the genuinely screened path at small n:
# prune="on" bypasses the auto size gate, small tiles/buckets give the
# kd-screen enough granularity to produce partial (sub-corpus) tiles
PRUNED = dict(prune="on", batch_rows=48, screen_bucket=8)


def _assert_same_sweep(data, metric, eps_list, **unpruned_kw):
    """Pruned and unpruned engines must agree bit-for-bit on counts and
    CSR at every eps; returns the pruned engine for further checks."""
    on = NeighborEngine(data, metric=metric, **PRUNED)
    off = NeighborEngine(data, metric=metric, prune="off",
                         batch_rows=48, **unpruned_kw)
    for eps in eps_list:
        c_on, csr_on = on.materialize(eps)
        c_off, csr_off = off.materialize(eps)
        np.testing.assert_array_equal(c_on, c_off)
        np.testing.assert_array_equal(csr_on.indptr, csr_off.indptr)
        np.testing.assert_array_equal(csr_on.indices, csr_off.indices)
        np.testing.assert_array_equal(csr_on.dists, csr_off.dists)
        np.testing.assert_array_equal(on.counts_only(eps),
                                      off.counts_only(eps))
    return on


@pytest.mark.parametrize("name", ALL_METRICS)
def test_pruned_byte_identical_every_metric(name):
    m = get_metric(name)
    rng = np.random.default_rng(11)
    data = m.synthesize(rng, 230)
    eng = NeighborEngine(data, metric=m, batch_rows=48)
    dense = eng.distances_from(np.arange(eng.n))
    off = dense[~np.eye(eng.n, dtype=bool)]
    eps_list = [float(np.quantile(off, q)) for q in (0.02, 0.2, 0.6)]
    on = _assert_same_sweep(data, m, eps_list)
    pr = on.last_materialize["pruning"]
    # screened iff the metric publishes a projection/lower-bound pair
    assert pr["screened"] == (
        m.project(m.canonicalize(data), 8) is not None)


def test_adversarial_everything_hits():
    """eps covering the whole dataset: no tile may be skipped into a
    wrong answer — the hybrid escape sweeps full tiles and the result
    still matches bit-for-bit."""
    rng = np.random.default_rng(5)
    x = rng.normal(scale=0.05, size=(300, 6)).astype(np.float32)
    on = _assert_same_sweep(x, "euclidean", [10.0, 1.0])
    assert on.last_materialize["pruning"]["screened"]


def test_adversarial_far_blobs_skip_tiles():
    """Well-separated blobs at small eps: the screen must actually skip
    cross-blob tiles (the point of the tentpole), exactly."""
    rng = np.random.default_rng(7)
    centers = rng.normal(scale=200.0, size=(5, 6))
    x = np.concatenate([c + rng.normal(size=(70, 6)) for c in centers]
                       ).astype(np.float32)
    on = _assert_same_sweep(x, "euclidean", [1.5])
    pr = on.last_materialize["pruning"]
    assert pr["screened"] and pr["tiles_skipped"] > 0
    assert pr["candidate_fraction"] < 0.7


def test_adversarial_duplicates_and_zero_rows():
    """Exact duplicates (zero screen distance, ties everywhere) and
    all-zero rows (the cosine indicator-coordinate convention) survive
    pruning bit-for-bit."""
    rng = np.random.default_rng(9)
    base = rng.normal(size=(60, 5)).astype(np.float32)
    x = np.concatenate([base, base, base[:30],
                        np.zeros((20, 5), np.float32)])
    _assert_same_sweep(x, "euclidean", [0.0, 0.8])
    _assert_same_sweep(x, "cosine", [0.0, 0.3, 1.0])


def test_no_lower_bound_metric_falls_back_unscreened():
    """A user CallableMetric has no projection: prune='on' must degrade
    to the plain sweep (screened=False), not crash or mis-prune."""
    def linf(x, y):
        import jax.numpy as jnp
        return jnp.abs(x[:, None, :] - y[None, :, :]).max(-1)

    m = CallableMetric("linf-prop", linf)
    rng = np.random.default_rng(13)
    x = rng.normal(size=(160, 4)).astype(np.float32)
    on = _assert_same_sweep(x, m, [0.6])
    assert on.last_materialize["pruning"] == {"screened": False}


def test_insert_strip_reuses_screen_exactly():
    """Incremental inserts ride the screened strip: the mutated index
    must stay byte-identical to a fresh pruned AND a fresh unpruned
    build over the concatenated dataset."""
    rng = np.random.default_rng(17)
    centers = rng.normal(scale=40.0, size=(4, 6))
    x = np.concatenate([c + rng.normal(size=(90, 6)) for c in centers]
                       ).astype(np.float32)
    eng = NeighborEngine(x[:330], **PRUNED)
    idx = FinexIndex.from_engine(eng, eps=1.4, minpts=6)
    idx.insert(x[330:])
    ref = FinexIndex.build(x, eps=1.4, minpts=6, batch_rows=48)
    np.testing.assert_array_equal(idx.csr.indptr, ref.csr.indptr)
    np.testing.assert_array_equal(idx.csr.indices, ref.csr.indices)
    np.testing.assert_array_equal(idx.csr.dists, ref.csr.dists)
    np.testing.assert_array_equal(idx.ordering.order, ref.ordering.order)
    assert idx.stats()["pruning"]["screened"]


def test_mesh_pruned_build_byte_identical():
    """Sharded screened emit on an 8-device host mesh == unpruned
    single-device CSR, divisible and ragged n, with skipping geometry."""
    code = """
    import numpy as np
    from repro.launch.mesh import make_host_mesh
    from repro.neighbors.distributed import sharded_csr_materialize
    from repro.neighbors.engine import NeighborEngine

    rng = np.random.default_rng(21)
    mesh = make_host_mesh(2, 4)
    centers = rng.normal(scale=60.0, size=(4, 6))
    for n in (512, 500):
        x = np.concatenate([c + rng.normal(size=(n // 4, 6))
                            for c in centers]).astype(np.float32)
        csr = sharded_csr_materialize(x, 1.2, mesh, cap=256, row_chunk=64)
        _, ref = NeighborEngine(x, prune="off").materialize(1.2)
        np.testing.assert_array_equal(csr.indptr, ref.indptr)
        np.testing.assert_array_equal(csr.indices, ref.indices)
        np.testing.assert_array_equal(csr.dists, ref.dists)
    print("MESH-PRUNED-OK")
    """
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=900)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr[-4000:]}"
    assert "MESH-PRUNED-OK" in p.stdout
