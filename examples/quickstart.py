"""Quickstart: build a FINEX index once, explore clusterings interactively.

Reproduces the paper's core workflow (Fig. 1): a dataset with clusters at
two different densities has no single good (ε, MinPts) — FINEX answers
every tighter setting exactly from one build, all through the
``FinexIndex`` facade (one build / many queries).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Eps, FinexIndex, MinPts, dbscan_from_csr
from repro.data.synthetic import two_scale_blobs
from repro.service import SweepPlanner


def describe(name, labels):
    n_clusters = labels.max() + 1 if (labels >= 0).any() else 0
    sizes = sorted((int((labels == k).sum()) for k in range(n_clusters)),
                   reverse=True)
    print(f"  {name:28s} clusters={n_clusters:2d} sizes={sizes[:6]} "
          f"noise={(labels < 0).sum()}")


def main():
    x = two_scale_blobs(1200, seed=0)

    # one build at a permissive generating pair ...
    eps, minpts = 0.5, 10
    index = FinexIndex.build(x, eps=eps, minpts=minpts)
    st = index.stats()
    print(f"built FINEX index: n={st['n']}, generating "
          f"(eps={eps}, MinPts={minpts}), cores={st['cores']}, "
          f"csr_nnz={st['csr_nnz']}")

    # ... then every clustering below it is an exact query
    print("\nε*-queries (exact, no re-clustering):")
    for eps_star in (0.5, 0.3, 0.2, 0.12):
        describe(f"eps*={eps_star}", index.eps_star(eps_star))

    print("\nMinPts*-queries (exact, OPTICS cannot do this at all):")
    for minpts_star in (10, 25, 60):
        describe(f"MinPts*={minpts_star}", index.minpts_star(minpts_star))

    # ...or answer a whole mixed grid in ONE batched pass — the serving
    # hot path (repro.service): scan, sparse clustering, verification
    # distances and core components are shared across the K settings
    print("\nbatched sweep (one pass, byte-identical to the loops above):")
    # settings are typed (Eps/MinPts/Hierarchy from repro.core); bare
    # ("eps", v) tuples keep working through the same normalization
    grid = [Eps(0.3), Eps(0.2), MinPts(25), MinPts(60)]
    for s, row in zip(grid, SweepPlanner(index).sweep(grid)):
        describe(f"sweep {s.kind}*={s.value}", row)

    # ---- hierarchy as a query: ALL scales from the one build -----------
    # the ordering + CSR already encode the complete density hierarchy;
    # hierarchy() condenses it into an HDBSCAN*-style cluster tree
    # (birth/death ε, sizes, stabilities) with ZERO new distance
    # computations, and its cuts are label-identical to the queries above
    print("\ncondensed cluster tree (every (ε*, MinPts*) at once):")
    h = index.hierarchy()
    print(f"  {h.n_clusters} condensed clusters over {h.cores.size} cores,"
          f" {h.n_selected} stability-selected, built in "
          f"{h.build_seconds * 1e3:.1f} ms — zero distance computations")
    describe("stability extraction", h.extract())
    assert np.array_equal(h.cut(0.2), index.eps_star(0.2))
    assert np.array_equal(h.cut_minpts(25), index.minpts_star(25))
    print("  cut(0.2) / cut_minpts(25) label-identical to the queries: ok")

    # the index round-trips through one npz file; MinPts*-queries need no
    # raw data at all, ε*-queries re-attach the engine via data=
    index.save("/tmp/finex_quickstart.npz")
    reloaded = FinexIndex.load("/tmp/finex_quickstart.npz", data=x)
    assert np.array_equal(reloaded.minpts_star(25), index.minpts_star(25))
    print("\nsave/load roundtrip: ok")

    # sanity: linear-time scan at the generating pair == DBSCAN
    lab = index.clustering()
    oracle = dbscan_from_csr(index.csr, index.engine.weights, eps, minpts)
    same_noise = ((lab < 0) == (oracle < 0)).all()
    print(f"linear scan at eps*=eps exact vs DBSCAN (noise match): "
          f"{bool(same_noise)}")

    # the index is maintainable, not a frozen snapshot: insert/delete
    # are exact deltas — only the new rows' distance strips are computed,
    # the CSR is spliced, and only the affected components re-sweep —
    # then every query above keeps working, still exactly
    print("\nincremental maintenance (exact deltas, then requery):")
    rng = np.random.default_rng(1)
    arrivals = (x[0] + 0.02 * rng.normal(size=(24, x.shape[1]))
                ).astype(x.dtype)          # 24 arrivals inside one cluster
    # rebuild_threshold: past this affected fraction the ordering repair
    # falls back (loudly) to a full re-sweep — still exact, never O(n²)
    rep = index.insert(arrivals, rebuild_threshold=0.6)
    print(f"  insert {rep['count']:3d} pts: mode={rep['mode']}, "
          f"affected {rep['affected']}/{rep['n']} rows, "
          f"version {rep['version']}")
    describe("after insert", index.clustering())
    departed = np.arange(index.n - 12, index.n)    # newest 12 leave again
    rep = index.delete(departed, rebuild_threshold=0.6)
    print(f"  delete {rep['count']:3d} pts: mode={rep['mode']}, "
          f"affected {rep['affected']}/{rep['n']} rows, "
          f"version {rep['version']}")
    describe("after delete", index.clustering())
    mutated = np.delete(np.concatenate([x, arrivals]), departed, axis=0)
    check = FinexIndex.build(mutated, eps=eps, minpts=minpts)
    assert np.array_equal(index.clustering(), check.clustering())
    assert np.array_equal(index.eps_star(0.2), check.eps_star(0.2))
    print("  byte-identical to a fresh build over the mutated data: ok")

    # ---- concurrent serving: the same index behind traffic -------------
    # ServiceFrontend turns the facade into a server: client threads
    # submit(op) and get Futures back, a bounded intake queue applies
    # admission control, and a windowed dispatcher coalesces each
    # index's mutations into ONE batched delta before its reads run —
    # every response still byte-identical to sequential application
    print("\nconcurrent front-end (4 client threads, coalesced windows):")
    import threading

    from repro.service import (BuildOp, ClusterOp, MutateRequest,
                               ServiceFrontend, SweepOp)

    fe = ServiceFrontend(workers=2, window=16)
    fe.submit(BuildOp("demo", x, eps, minpts)).result()
    results = []
    lock = threading.Lock()

    def client(tid):
        rng = np.random.default_rng(10 + tid)
        for _ in range(4):
            if rng.random() < 0.3:
                pt = (x[0] + 0.02 * rng.normal(size=(1, x.shape[1]))
                      ).astype(x.dtype)
                req = MutateRequest("demo", "insert", points=pt)
            elif rng.random() < 0.5:
                req = SweepOp("demo", [("eps", 0.3), ("minpts", 25)])
            else:
                req = ClusterOp("demo")
            with lock:
                results.append(fe.submit(req))

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fe.shutdown(drain=True, timeout=120)     # graceful: flushes windows
    versions = [r.result().version for r in results]
    print(f"  {len(results)} responses from 4 threads: "
          f"{fe.windows} windows, {fe.batched_deltas} coalesced deltas, "
          f"final version {max(versions)}")
    assert all(r.exception() is None for r in results)
    print("  graceful drain, every Future resolved: ok")


if __name__ == "__main__":
    main()
