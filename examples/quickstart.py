"""Quickstart: build a FINEX index once, explore clusterings interactively.

Reproduces the paper's core workflow (Fig. 1): a dataset with clusters at
two different densities has no single good (ε, MinPts) — FINEX answers
every tighter setting exactly from one build.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (dbscan_from_csr, eps_star_query, finex_build,
                        minpts_star_query, query_clustering)
from repro.data.synthetic import two_scale_blobs
from repro.neighbors.engine import NeighborEngine


def describe(name, labels):
    n_clusters = labels.max() + 1 if (labels >= 0).any() else 0
    sizes = sorted((int((labels == k).sum()) for k in range(n_clusters)),
                   reverse=True)
    print(f"  {name:28s} clusters={n_clusters:2d} sizes={sizes[:6]} "
          f"noise={(labels < 0).sum()}")


def main():
    x = two_scale_blobs(1200, seed=0)
    engine = NeighborEngine(x, metric="euclidean")

    # one build at a permissive generating pair ...
    eps, minpts = 0.5, 10
    index, csr = finex_build(engine, eps, minpts)
    print(f"built FINEX index: n={engine.n}, generating "
          f"(eps={eps}, MinPts={minpts})")

    # ... then every clustering below it is an exact query
    print("\nε*-queries (exact, no re-clustering):")
    for eps_star in (0.5, 0.3, 0.2, 0.12):
        labels = eps_star_query(index, engine, eps_star)
        describe(f"eps*={eps_star}", labels)

    print("\nMinPts*-queries (exact, OPTICS cannot do this at all):")
    for minpts_star in (10, 25, 60):
        labels = minpts_star_query(index, csr, minpts_star)
        describe(f"MinPts*={minpts_star}", labels)

    # sanity: linear-time scan at the generating pair == DBSCAN
    lab = query_clustering(index, eps)
    oracle = dbscan_from_csr(csr, engine.weights, eps, minpts)
    same_noise = ((lab < 0) == (oracle < 0)).all()
    print(f"\nlinear scan at eps*=eps exact vs DBSCAN (noise match): "
          f"{bool(same_noise)}")


if __name__ == "__main__":
    main()
