"""Custom-metric quickstart: cluster STRINGS under a user-defined distance.

FINEX's flexibility claim (paper claim (d)) is that the index is oblivious
to data types and distance functions — only the neighborhood
materialization touches raw data. This example exercises that end to end
with a data type the repo never special-cased: variable-length strings
under a user-defined per-position mismatch distance, registered at
runtime with ``register_metric``. No Pallas kernel, no engine changes —
the registered callable rides the dense fallback path of the metric
protocol, and every FINEX feature (exact ε*/MinPts*-queries, npz
round-trip, the serving-side ``IndexStore``) just works.

Projection pruning is opt-in for custom metrics: a registered callable
sweeps unpruned (always correct) unless you also pass ``project=`` —
a host function returning a float64 screen embedding whose euclidean
distance, mapped through ``lower_bound=`` (identity by default),
lower-bounds the true distance.  The engine then provably skips
distance tiles while the CSR stays byte-identical; see the registration
below for a working bound under the mismatch distance.

    PYTHONPATH=src python examples/custom_metric.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import FinexIndex
from repro.metrics import register_metric, registered_metrics
from repro.neighbors.engine import NeighborEngine
from repro.service import IndexStore

MAX_LEN = 16


def encode_strings(words, max_len: int = MAX_LEN) -> np.ndarray:
    """Strings → (n, max_len) uint8 codepoints, zero-padded (0 = no char).

    The encoded matrix is the metric's canonical data — it is what gets
    fingerprinted, uploaded, and swept tile-by-tile.
    """
    out = np.zeros((len(words), max_len), dtype=np.uint8)
    for i, w in enumerate(words):
        codes = np.frombuffer(w[:max_len].encode("ascii", "replace"),
                              dtype=np.uint8)
        out[i, :len(codes)] = codes
    return out


def string_mismatch(a, b):
    """Per-position mismatch rate between padded string rows.

    d(r, s) = (#positions where the strings differ, length overhang
    included) / max(len(r), len(s)) — 0 for identical strings, 1 for
    fully disjoint ones. Pure jnp on the (m, n, L) broadcast: exactly the
    kind of small, readable distance a user plugs in.
    """
    neq = a[:, None, :] != b[None, :, :]
    both_pad = (a[:, None, :] == 0) & (b[None, :, :] == 0)
    diff = (neq & ~both_pad).sum(-1)
    len_a = (a != 0).sum(-1)[:, None]
    len_b = (b != 0).sum(-1)[None, :]
    denom = jnp.maximum(jnp.maximum(len_a, len_b), 1)
    return (diff / denom).astype(jnp.float32)


def string_screen(canon, k, seed=0):
    """Opt-in prune screen: per-position one-hot of (codepoint mod 8).

    Squared screen distance is H'/L where H' counts positions whose
    *hashed* codes differ — H' <= H (collisions only lose mismatches)
    and the true distance is H / max(len) >= H / L, so
    ``lower_bound(s) = s**2`` is a provable lower bound and the engine
    may skip any tile whose bound already exceeds ε.
    """
    a = canon[0].astype(np.int64)
    n, L = a.shape
    onehot = np.zeros((n, L, 8))
    np.put_along_axis(onehot, (a % 8)[..., None], 1.0, axis=2)
    return onehot.reshape(n, L * 8) / np.sqrt(2.0 * L)


# one line makes the distance a first-class metric: resolvable by name
# everywhere the repo says metric=..., fingerprint-aware, npz-persistent.
# project=/lower_bound= are optional — leave them off and the metric
# simply rides the (always correct) unpruned sweep
if "string-mismatch" not in registered_metrics():
    register_metric("string-mismatch", string_mismatch, dtype=np.uint8,
                    project=string_screen, lower_bound=np.square)


def make_corpus(seed: int = 0):
    """A few word families plus mutated variants and random noise."""
    rng = np.random.default_rng(seed)
    families = ["tokenizer", "clustering", "manifold", "density"]
    alphabet = np.frombuffer(b"abcdefghijklmnopqrstuvwxyz", dtype=np.uint8)
    words, truth = [], []
    for f_id, base in enumerate(families):
        for _ in range(40):
            chars = bytearray(base.encode())
            for pos in rng.choice(len(chars), size=rng.integers(0, 3),
                                  replace=False):
                chars[pos] = int(rng.choice(alphabet))
            words.append(chars.decode())
            truth.append(f_id)
    for _ in range(25):                       # unstructured noise strings
        length = int(rng.integers(5, MAX_LEN))
        words.append(bytes(rng.choice(alphabet, size=length)).decode())
        truth.append(-1)
    order = rng.permutation(len(words))
    return [words[i] for i in order], np.asarray(truth)[order]


def describe(name, labels):
    n_clusters = labels.max() + 1 if (labels >= 0).any() else 0
    sizes = sorted((int((labels == k).sum()) for k in range(n_clusters)),
                   reverse=True)
    print(f"  {name:24s} clusters={n_clusters:2d} sizes={sizes[:6]} "
          f"noise={(labels < 0).sum()}")


def main():
    words, truth = make_corpus()
    data = encode_strings(words)

    index = FinexIndex.build(data, eps=0.45, minpts=5,
                             metric="string-mismatch")
    st = index.stats()
    print(f"built FINEX index over {st['n']} strings "
          f"(metric={st['metric']}, cores={st['cores']}, "
          f"csr_nnz={st['csr_nnz']})")

    labels = index.clustering()
    describe("generating (0.45, 5)", labels)
    for f_id, word in [(0, "tokenizer"), (1, "clustering"),
                       (2, "manifold"), (3, "density")]:
        members = [w for w, l, t in zip(words, labels, truth)
                   if l >= 0 and t == f_id]
        print(f"    family {word!r:13s} -> {len(members)} clustered, "
              f"e.g. {sorted(members)[:3]}")

    print("\ntighter settings are exact queries, same as any metric:")
    for eps_star in (0.35, 0.25, 0.15):
        describe(f"eps*={eps_star}", index.eps_star(eps_star))
    describe("MinPts*=12", index.minpts_star(12))

    # the registry name + params round-trip through the npz archive;
    # load resolves them back through the registry
    index.save("/tmp/finex_strings.npz")
    reloaded = FinexIndex.load("/tmp/finex_strings.npz", data=data)
    assert np.array_equal(reloaded.minpts_star(12), index.minpts_star(12))
    print("\nsave/load roundtrip under the custom metric: ok")

    # and the serving layer keys it like any built-in: a warm hit costs
    # zero distance computations
    store = IndexStore(capacity=2)
    store.put(index)
    _, outcome = store.get_or_build(data, eps=0.45, minpts=5,
                                    metric="string-mismatch")
    print(f"IndexStore second lookup: {outcome!r}")

    # the registered project=/lower_bound= pair lets the engine provably
    # skip distance tiles (automatic for large datasets; forced here to
    # show the report). On a few hundred shuffled strings the ball
    # bounds rarely rule out a whole tile — the skip rate is a
    # large-dataset effect — but the contract holds at every size: the
    # CSR stays byte-identical to the unpruned sweep
    eng = NeighborEngine(data, metric="string-mismatch", prune="on",
                         batch_rows=32)
    _, csr_on = eng.materialize(0.15)
    print("\npruned sweep at eps=0.15:", eng.last_materialize["pruning"])
    _, csr_off = NeighborEngine(data, metric="string-mismatch",
                                prune="off").materialize(0.15)
    assert np.array_equal(csr_on.indices, csr_off.indices)
    assert np.array_equal(csr_on.dists, csr_off.dists)
    print("byte-identical to the unpruned sweep: ok")


if __name__ == "__main__":
    main()
