"""Fault-tolerance walkthrough: train, get preempted, resume bit-exact.

    PYTHONPATH=src python examples/train_resume.py
"""
import os
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def main():
    ckpt = tempfile.mkdtemp(prefix="finex_resume_")
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "deepseek-7b", "--smoke", "--steps", "24", "--batch", "4",
            "--seq-len", "64", "--ckpt-every", "8", "--ckpt-dir", ckpt,
            "--log-every", "4"]
    print("=== run 1: preempted hard at step 16 ===")
    p = subprocess.run(base + ["--preempt-at", "16"], env=ENV, cwd=REPO)
    assert p.returncode == 42      # the simulated kill
    print("\n=== run 2: same command — auto-resumes from step 16 ===")
    subprocess.run(base, env=ENV, cwd=REPO, check=True)
    print("\n(final losses are bit-identical to an uninterrupted run — "
          "see tests/test_checkpoint.py::test_preemption_resume_bit_exact)")


if __name__ == "__main__":
    main()
