"""End-to-end driver: FINEX-curated data → train a ~100M-class LM.

The paper's technique as a first-class framework feature: documents are
clustered under Jaccard over token n-gram sets (the paper's process-mining
set modeling); near-duplicate clusters are downsampled; then a reduced
minicpm-family model trains on the curated stream. Dedup aggressiveness is
re-tuned interactively via exact ε*/MinPts*-queries WITHOUT re-clustering.

    PYTHONPATH=src python examples/data_curation.py [--steps 200]
"""
import argparse

import numpy as np

from repro.data.curation import curate_corpus


def synth_corpus(n_templates=40, dups_per=25, n_unique=400, seed=0):
    rng = np.random.default_rng(seed)
    docs = []
    for _ in range(n_templates):
        base = list(rng.integers(0, 480, size=64))
        for _ in range(dups_per):
            d = list(base)
            for _ in range(int(rng.integers(0, 4))):
                d[int(rng.integers(len(d)))] = int(rng.integers(480))
            docs.append(d)
    docs += [list(rng.integers(0, 480, size=64)) for _ in range(n_unique)]
    return docs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    docs = synth_corpus()
    print(f"corpus: {len(docs)} documents "
          f"(40 duplicate families + 400 unique)")

    report = curate_corpus(docs, eps=0.3, minpts=8, ngram=2,
                           keep_per_cluster=2)
    print(f"FINEX curation: {report.n_clusters} near-duplicate clusters, "
          f"{report.n_noise} unique docs, "
          f"{len(report.kept_indices)}/{len(docs)} kept")

    # interactive retuning — exact, no rebuild (the paper's headline)
    for eps_star in (0.2, 0.1):
        r = report.retune(eps_star=eps_star)
        print(f"  retune eps*={eps_star}: {r.n_clusters} clusters, "
              f"kept {len(r.kept_indices)}")
    for minpts_star in (16, 64):
        r = report.retune(minpts_star=minpts_star)
        print(f"  retune MinPts*={minpts_star}: {r.n_clusters} clusters, "
              f"kept {len(r.kept_indices)}")

    # train a reduced minicpm (WSD schedule, per its paper) on the stream
    print("\ntraining reduced minicpm on the curated stream "
          f"({args.steps} steps):")
    from repro.launch.train import main as train_main
    train_main(["--arch", "minicpm-2b", "--smoke", "--schedule", "wsd",
                "--steps", str(args.steps), "--batch", "8",
                "--seq-len", "128", "--lr", "3e-3", "--log-every", "20"])


if __name__ == "__main__":
    main()
