"""Batched serving example: slot-based continuous batching over the
hymba hybrid (SWA + SSM cache) with greedy decoding.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import RunConfig, ShapeConfig, get_arch
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_arch("hymba-1.5b").reduced()
    rc = RunConfig(model=cfg, shape=ShapeConfig("serve", 96, 4, "decode"),
                   remat=False, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    requests = [Request(prompt=rng.integers(0, cfg.vocab, size=12)
                        .astype(np.int32), max_new=16) for _ in range(8)]
    engine = ServeEngine(params, cfg, rc, batch_slots=4, max_seq=64)
    t0 = time.time()
    engine.run(requests)
    dt = time.time() - t0
    total = sum(len(r.out) for r in requests)
    print(f"served {len(requests)} requests / {total} tokens in {dt:.1f}s "
          f"({total / dt:.1f} tok/s on CPU, {engine.decode_steps} steps)")
    for i, r in enumerate(requests[:4]):
        print(f"  req{i}: {r.out}")


if __name__ == "__main__":
    main()
